"""Serving-tier contracts: continuous batching, AOT warmup, placement.

The tentpole claims, as tests:
- batched serving is BIT-EXACT with single-query ``rank_batch`` (padding
  rows are inert, per-request top-k reproduces ``lax.top_k`` tie-break);
- the flush policy triggers on full buckets AND on deadlines (a lone
  query is never stranded);
- AOT warmup leaves zero compiles and zero cold-start overflow for the
  warmed shapes, resets stats/EMA, and keeps the seeded peaks;
- the single-device placement path is the identity and the 1×1-mesh path
  is numerically indistinguishable from it.
"""

import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax._src.test_util as jtu

from repro.core.lear import LearClassifier
from repro.forest.ensemble import random_ensemble
from repro.serve.batching import BucketPolicy, ContinuousBatcher
from repro.serve.errors import BatcherStopped
from repro.serve.placement import local, single_device
from repro.serve.ranking_service import RankingService, ServiceConfig
from repro.serve.tier import ServingTier, TierConfig
from repro.serve.warmup import enable_persistent_cache, warmup_service

F = 12


def _service(seed=0, sentinels=(8, 28), **knobs):
    ens = random_ensemble(seed, n_trees=64, depth=4, n_features=F)
    clfs = [
        LearClassifier(
            forest=random_ensemble(100 + i, n_trees=10, depth=3, n_features=16),
            sentinel=s,
        )
        for i, s in enumerate(sentinels)
    ]
    knobs.setdefault("execution_mode", "fused")
    knobs.setdefault("launch_overhead_trees", 512.0)
    svc = RankingService(
        ens, clfs[0], ServiceConfig(threshold=0.4, **knobs),
        extra_classifiers=clfs[1:],
    )
    # Deterministic stage gate (continue ⇔ feature 0 positive), installed
    # before any trace — keeps survivor counts exact and compiles cheap.
    gate = lambda p, m, features=None: m & (features[..., 0] > 0.0)
    svc.stage_strategies = [gate] * len(svc.sentinels)
    return svc


def _queries(rng, n, lo=20, hi=32):
    qs = []
    for _ in range(n):
        q = rng.normal(size=(int(rng.integers(lo, hi + 1)), F))
        qs.append(q.astype(np.float32))
    return qs


def test_policy_buckets():
    p = BucketPolicy(max_queries=8, min_docs=8, max_docs=256)
    assert p.doc_bucket(1) == 8 and p.doc_bucket(9) == 16
    assert p.doc_bucket(256) == 256
    assert p.query_bucket(1) == 1 and p.query_bucket(3) == 4
    assert p.query_bucket(100) == 8  # clipped at max_queries
    assert p.buckets((20, 30)) == [(1, 32), (2, 32), (4, 32), (8, 32)]
    assert p.buckets((20, 100)) == (
        [(q, 32) for q in (1, 2, 4, 8)] + [(q, 128) for q in (1, 2, 4, 8)]
    )
    with pytest.raises(AssertionError):
        BucketPolicy(max_queries=6)  # not a power of two


def test_batcher_packs_and_is_bitexact():
    """Many concurrent ragged queries → fewer engine batches, every
    response identical to submitting that query alone."""
    rng = np.random.default_rng(0)
    svc = _service()
    b = ContinuousBatcher(
        svc, F, BucketPolicy(max_queries=4, max_wait_ms=50.0)
    )
    b.start()
    queries = _queries(rng, 12)
    futs = [b.submit(q) for q in queries]
    results = [f.result(timeout=120) for f in futs]
    b.stop()

    assert b.stats.completed == 12 and b.stats.failed == 0
    assert b.stats.flushes_full >= 1
    assert svc.stats.batches < 12, "batcher did not pack"
    assert svc.stats.queries == 12

    ref = _service()  # fresh service: no shared adaptive state
    for q, (top, scores) in zip(queries, results):
        t_ref, s_ref = ref.rank_batch(
            jnp.asarray(q[None]), jnp.ones((1, q.shape[0]), bool)
        )
        np.testing.assert_array_equal(scores, np.asarray(s_ref)[0])
        k = min(ref.top_k, q.shape[0])
        np.testing.assert_array_equal(top, np.asarray(t_ref)[0][:k])


def test_deadline_flush_frees_a_lone_query():
    svc = _service()
    b = ContinuousBatcher(svc, F, BucketPolicy(max_queries=8, max_wait_ms=5.0))
    b.start()
    q = np.random.default_rng(1).normal(size=(16, F)).astype(np.float32)
    top, scores = b.submit(q).result(timeout=120)
    assert scores.shape == (16,) and top.shape == (10,)
    b.stop()
    assert b.stats.flushes_deadline == 1 and b.stats.flushes_full == 0


def test_batcher_propagates_engine_errors():
    svc = _service()
    svc.rank_batch = lambda *a, **k: (_ for _ in ()).throw(RuntimeError("boom"))
    b = ContinuousBatcher(svc, F, BucketPolicy(max_queries=2, max_wait_ms=5.0))
    b.start()
    futs = [b.submit(np.zeros((8, F), np.float32)) for _ in range(2)]
    for f in futs:
        with pytest.raises(RuntimeError, match="boom"):
            f.result(timeout=60)
    b.stop()
    assert b.stats.failed == 2 and b.stats.completed == 0


def test_warmup_no_recompiles_no_cold_start_overflow():
    """After warmup of a (Q, D) bucket: serving a dense batch of that shape
    triggers ZERO jit lowerings and ZERO overflow (capacities were seeded
    at the physical max), and the warmup's own traffic left no stats."""
    svc = _service(execution_mode="auto")
    report = warmup_service(svc, F, [(2, 64)])
    assert report.buckets == [(2, 64)]
    assert svc.stats.batches == 0  # warmup is not traffic
    state = svc.bucket_state(2, 64)
    assert state.peaks == [128] * len(svc.sentinels)  # kept
    assert state.ema is None  # cleared

    X = np.random.default_rng(2).normal(size=(2, 64, F)).astype(np.float32)
    X[..., 0] = 1.0  # every document survives every stage
    X, mask = jnp.asarray(X), jnp.ones((2, 64), bool)
    with jtu.count_jit_and_pmap_lowerings() as count:
        svc.rank_batch(X, mask)
        svc.rank_batch(X, mask)
    assert count[0] == 0, f"{count[0]} recompiles after warmup"
    assert svc.stats.overflow_docs == 0
    # Without warmup the same dense batch DOES overflow its cold-start
    # capacity — the guarantee above is the warmup, not the workload.
    cold = _service(execution_mode="auto")
    cold.rank_batch(X, mask)
    assert cold.stats.overflow_docs > 0


def test_tier_end_to_end_stats_and_drain():
    svc = _service()
    tier = ServingTier(
        svc, F,
        TierConfig(doc_counts=(32,), warmup=True, persistent_cache=False),
        policy=BucketPolicy(max_queries=2, max_wait_ms=20.0),
    )
    tier.start()
    rng = np.random.default_rng(3)
    futs = [tier.submit(q) for q in _queries(rng, 5)]
    res = [f.result(timeout=120) for f in futs]
    tier.stop()
    assert len(res) == 5
    s = tier.stats()
    assert s["batcher"]["completed"] == 5
    assert s["service"]["queries"] == 5
    assert s["service"]["overflow_docs"] == 0
    assert s["warmup_seconds"] > 0
    assert s["n_devices"] == 1
    # Restart after stop is allowed; submit after stop gets the typed stop.
    with pytest.raises(BatcherStopped):
        tier.submit(_queries(rng, 1)[0])
    # The health surface outlives the worker: state + queue are readable.
    h = tier.health()
    assert h["state"] == "stopped" and h["queue_depth"] == 0
    assert h["crashes"] == 0 and not h["started"]


def test_single_device_placement_is_identity_and_local_mesh_bitexact():
    X = jnp.asarray(
        np.random.default_rng(4).normal(size=(2, 32, F)).astype(np.float32)
    )
    mask = jnp.ones((2, 32), bool)
    sd = single_device()
    assert sd.put(X, mask) == (X, mask) and sd.n_devices == 1

    svc_a, svc_b = _service(), _service()
    t_a, s_a = svc_a.rank_batch(X, mask)
    t_b, s_b = svc_b.rank_batch(X, mask, placement=local())
    np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))
    np.testing.assert_array_equal(np.asarray(t_a), np.asarray(t_b))


_MULTIDEV_PROG = r"""
import numpy as np
import jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.core.lear import LearClassifier
from repro.forest.ensemble import random_ensemble
from repro.serve.placement import data_parallel, single_device
from repro.serve.ranking_service import RankingService, ServiceConfig

def service():
    ens = random_ensemble(0, n_trees=16, depth=2, n_features=6)
    clf = LearClassifier(
        forest=random_ensemble(7, n_trees=4, depth=2, n_features=10),
        sentinel=8,
    )
    svc = RankingService(ens, clf, ServiceConfig(
        threshold=0.4, execution_mode="fused", launch_overhead_trees=512.0,
    ))
    svc.stage_strategies = [
        lambda p, m, features=None: m & (features[..., 0] > 0.0)
    ]
    return svc

pl = data_parallel()
assert pl.n_devices == 8
X = jnp.asarray(np.random.default_rng(0)
                .normal(size=(8, 16, 6)).astype(np.float32))
mask = jnp.ones((8, 16), bool)
Xs, ms = pl.put(X, mask)
# The query axis really is split 8 ways...
assert len(Xs.sharding.device_set) == 8, Xs.sharding
top_s, sc_s = service().rank_batch(Xs, ms)
# ...and a non-divisible Q degrades to replication instead of crashing.
X1, m1 = pl.put(X[:1], mask[:1])
assert len(X1.sharding.device_set) == 8  # replicated across the mesh
service().rank_batch(X1, m1)

top_r, sc_r = service().rank_batch(*single_device().put(X, mask))
np.testing.assert_allclose(np.asarray(sc_s), np.asarray(sc_r),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_array_equal(np.asarray(top_s), np.asarray(top_r))
print("MULTIDEV_OK")
"""


def test_data_parallel_placement_8_devices():
    """The sharded serving path on a forced 8-device CPU: query axis split
    across the mesh, results matching the single-device reference."""
    res = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_PROG],
        capture_output=True, text=True, timeout=570,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        },
        cwd="/root/repo",
    )
    assert "MULTIDEV_OK" in res.stdout, res.stdout + res.stderr


def test_enable_persistent_cache_points_jax_at_dir(tmp_path):
    before = jax.config.jax_compilation_cache_dir
    try:
        d = str(tmp_path / "xla-cache")
        got = enable_persistent_cache(d)
        assert got == d
        assert jax.config.jax_compilation_cache_dir == d
        import os
        assert os.path.isdir(d)
    finally:
        jax.config.update("jax_compilation_cache_dir", before)
