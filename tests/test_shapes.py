"""Runtime-checked lane for the kernel entry points' jaxtyping
annotations (tier-1).

``shape_checked`` enforces the declared shapes/dtypes at call time with
dim variables bound across arguments — the node axis ``n`` on
``feature`` must be the SAME ``n`` as on ``threshold``/``mask_*``, and
the tree axis ``t`` must agree everywhere.  Production call sites stay
unwrapped; this lane proves the annotations are truthful.
"""

from __future__ import annotations

import jax.numpy as jnp
import pytest

jaxtyping = pytest.importorskip("jaxtyping")

from repro.kernels.forest_score import (  # noqa: E402
    forest_score_pallas,
    forest_score_segments_pallas,
)
from repro.typecheck import shape_checked  # noqa: E402

B, F, T, N, L = 8, 4, 16, 8, 4


def _operands():
    return dict(
        x=jnp.zeros((B, F), jnp.float32),
        feature=jnp.zeros((T, N), jnp.int32),
        threshold=jnp.zeros((T, N), jnp.float32),
        mask_lo=jnp.full((T, N), 0xFFFFFFFF, jnp.uint32),
        mask_hi=jnp.full((T, N), 0xFFFFFFFF, jnp.uint32),
        leaf_value=jnp.zeros((T, L), jnp.float32),
    )


def test_plain_entry_accepts_declared_shapes():
    checked = shape_checked(forest_score_pallas)
    out = checked(**_operands(), block_b=B, block_t=T)
    assert out.shape == (B,)
    assert out.dtype == jnp.float32


def test_segments_entry_accepts_and_returns_b_s():
    checked = shape_checked(forest_score_segments_pallas)
    out = checked(
        **_operands(),
        seg_block_starts=(0,), n_tree_blocks=1, block_b=B, block_t=T,
    )
    assert out.shape == (B, 1)


def test_wrong_dtype_rejected():
    checked = shape_checked(forest_score_pallas)
    ops = _operands()
    ops["feature"] = ops["feature"].astype(jnp.float32)  # i32 contract
    with pytest.raises(TypeError, match="feature"):
        checked(**ops, block_b=B, block_t=T)


def test_cross_argument_dim_binding_rejected():
    # threshold's node axis disagrees with feature's — same letter `n`
    # in the annotation, so the binding must fail even though each
    # operand is a valid [t, n] float32/int32 on its own.
    checked = shape_checked(forest_score_pallas)
    ops = _operands()
    ops["threshold"] = jnp.zeros((T, 2 * N), jnp.float32)
    with pytest.raises(TypeError, match="threshold"):
        checked(**ops, block_b=B, block_t=T)


def test_wrong_rank_rejected():
    checked = shape_checked(forest_score_pallas)
    ops = _operands()
    ops["x"] = jnp.zeros((B,), jnp.float32)
    with pytest.raises(TypeError, match="`x`"):
        checked(**ops, block_b=B, block_t=T)


def test_unwrapped_entry_points_unchanged():
    # the hot path never pays for checking: the public names are the
    # raw jitted callables, not shape_checked wrappers
    assert not hasattr(forest_score_pallas, "__shape_checked__")
    out = forest_score_pallas(**_operands(), block_b=B, block_t=T)
    assert out.shape == (B,)
