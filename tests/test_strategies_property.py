"""Property-based tests (hypothesis) for the early-exit strategy family.

Randomized sweeps of the edge geometry the deterministic suites pin
pointwise: all-masked query rows, ``k_s ≥ D`` clamps, heavy score ties,
and single-document queries — for ``ert_continue`` / ``ept_continue`` /
``ideal_continue`` and the query-level ``query_converged`` predicate.

Module skips cleanly where hypothesis is not installed (the CI fast
lane has it; minimal local environments may not).
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.strategies import (  # noqa: E402
    ept_continue,
    ert_continue,
    ideal_continue,
    query_converged,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _problem(Q, D, alive_rate, ties, seed):
    rng = np.random.default_rng(seed)
    partial = rng.normal(size=(Q, D)).astype(np.float32)
    if ties:
        partial = np.round(partial)  # collapses scores onto few values
    mask = rng.random((Q, D)) < alive_rate
    return partial, mask, rng


@given(
    Q=st.integers(1, 5),
    D=st.integers(1, 24),
    k_s=st.integers(1, 40),
    alive_rate=st.floats(0.0, 1.0),
    ties=st.booleans(),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_ert_mask_and_clamp_properties(Q, D, k_s, alive_rate, ties, seed):
    """ERT never resurrects masked docs; k_s ≥ D keeps every masked doc
    (ranks are always < D); all-masked rows stay empty; per query at
    most min(k_s, n_alive) docs continue."""
    partial, mask, _ = _problem(Q, D, alive_rate, ties, seed)
    cont = np.asarray(
        ert_continue(jnp.asarray(partial), jnp.asarray(mask), k_s=k_s)
    )
    assert not (cont & ~mask).any()
    if k_s >= D:
        np.testing.assert_array_equal(cont, mask)
    per_query = cont.sum(axis=1)
    n_alive = mask.sum(axis=1)
    assert (per_query <= np.minimum(k_s, n_alive)).all()
    assert (per_query[n_alive == 0] == 0).all()


@given(
    Q=st.integers(1, 5),
    D=st.integers(1, 24),
    k_s=st.integers(1, 40),
    p=st.floats(0.0, 5.0),
    alive_rate=st.floats(0.0, 1.0),
    ties=st.booleans(),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_ept_mask_tie_and_threshold_properties(
    Q, D, k_s, p, alive_rate, ties, seed
):
    """EPT keeps exactly the alive docs with score ≥ σ_{k_s} − p (ties at
    the threshold INCLUDED — ≥, not >), never resurrects masked docs,
    and is mask-invariant (garbage at masked positions is ignored)."""
    partial, mask, rng = _problem(Q, D, alive_rate, ties, seed)
    cont = np.asarray(
        ept_continue(jnp.asarray(partial), jnp.asarray(mask), k_s=k_s, p=p)
    )
    assert not (cont & ~mask).any()
    # Reference semantics in numpy (kth best ALIVE score, clamped k).
    NEG = -1e30
    masked = np.where(mask, partial, NEG)
    kk = min(k_s, D)
    kth = np.sort(masked, axis=1)[:, ::-1][:, kk - 1]
    expect = mask & (partial >= (kth - p)[:, None])
    np.testing.assert_array_equal(cont, expect)
    # Mask-invariance: trash the masked positions, decision unchanged.
    trashed = partial.copy()
    trashed[~mask] = rng.normal(size=int((~mask).sum())) * 1e6
    again = np.asarray(
        ept_continue(jnp.asarray(trashed), jnp.asarray(mask), k_s=k_s, p=p)
    )
    np.testing.assert_array_equal(cont, again)


@given(
    Q=st.integers(1, 4),
    D=st.integers(1, 12),
    k=st.integers(1, 15),
    alive_rate=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_ideal_oracle_properties(Q, D, k, alive_rate, seed):
    """EE_ideal returns a per-query cut in [0, D], never resurrects
    masked docs, and the merged ranking at its cut reaches full-ensemble
    NDCG@k (the oracle's defining property)."""
    from repro.metrics.ranking import ndcg_at_k

    rng = np.random.default_rng(seed)
    partial = jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    full = partial + jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 5, size=(Q, D)).astype(np.float32))
    mask = jnp.asarray(rng.random((Q, D)) < alive_rate)
    cont, cut = ideal_continue(partial, full, labels, mask, k=k)
    cont, cut = np.asarray(cont), np.asarray(cut)
    assert ((0 <= cut) & (cut <= D)).all()
    assert not (cont & ~np.asarray(mask)).any()
    merged = jnp.where(jnp.asarray(cont), full, partial)
    got = np.asarray(ndcg_at_k(merged, labels, mask, k))
    ref = np.asarray(ndcg_at_k(full, labels, mask, k))
    assert (got >= ref - 1e-6).all()
    # All-masked rows: no doc continues.
    empty = ~np.asarray(mask).any(axis=1)
    assert not cont[empty].any()


@given(
    Q=st.integers(1, 5),
    D=st.integers(1, 24),
    k=st.integers(1, 30),
    margin=st.floats(0.0, 4.0),
    alive_rate=st.floats(0.0, 1.0),
    ties=st.booleans(),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_query_converged_mask_invariance(
    Q, D, k, margin, alive_rate, ties, seed
):
    """Garbage at non-alive positions must not change the predicate —
    the invariance staged execution (stale prefixes on exited docs)
    depends on."""
    partial, alive, rng = _problem(Q, D, alive_rate, ties, seed)
    trashed = partial.copy()
    trashed[~alive] = rng.normal(size=int((~alive).sum())) * 1e6
    a = query_converged(jnp.asarray(partial), jnp.asarray(alive), k, margin)
    b = query_converged(jnp.asarray(trashed), jnp.asarray(alive), k, margin)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(
    Q=st.integers(1, 4),
    D=st.integers(1, 16),
    k=st.integers(1, 20),
    m_lo=st.floats(0.0, 2.0),
    m_hi=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_query_converged_margin_monotonicity(Q, D, k, m_lo, m_hi, seed):
    """A harder (larger) margin converges a subset of what an easier one
    converges; margin=inf converges a subset of any finite margin."""
    lo, hi = sorted((m_lo, m_hi))
    rng = np.random.default_rng(seed)
    partial = jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    alive = jnp.asarray(rng.random((Q, D)) < 0.7)
    easy = np.asarray(query_converged(partial, alive, k, lo))
    hard = np.asarray(query_converged(partial, alive, k, hi))
    inf = np.asarray(query_converged(partial, alive, k, math.inf))
    assert not (hard & ~easy).any()
    assert not (inf & ~hard).any()


@given(
    D=st.integers(1, 16),
    k=st.integers(1, 20),
    margin=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_query_converged_empty_and_single_doc_rows(D, k, margin, seed):
    """All-masked rows always converge (even at margin=inf); a single
    alive doc converges under any finite margin (no challenger) but not
    at margin=inf (it is still alive)."""
    rng = np.random.default_rng(seed)
    partial = jnp.asarray(rng.normal(size=(2, D)).astype(np.float32))
    alive = np.zeros((2, D), bool)
    alive[1, rng.integers(D)] = True
    got_inf = np.asarray(
        query_converged(partial, jnp.asarray(alive), k, math.inf)
    )
    got_fin = np.asarray(
        query_converged(partial, jnp.asarray(alive), k, margin)
    )
    assert got_inf[0] and got_fin[0]          # empty row
    assert not got_inf[1] and got_fin[1]      # single alive doc
