"""Cross-process seed determinism for the synthetic LETOR generator.

Every experiment, bench table, and test fixture keys its data on
``make_letor_dataset(seed=...)``; a generator whose output drifted
across processes (hash randomization, import-order RNG pollution,
platform-dependent numpy paths) would silently decouple the benches
from the tests. Pinned here: the SAME seed in a FRESH interpreter
produces byte-identical arrays, and different seeds do not.
"""

import hashlib
import subprocess
import sys
from pathlib import Path

import numpy as np

from repro.data.synthetic import make_letor_dataset

SRC = str(Path(__file__).resolve().parent.parent / "src")

_CHILD = r"""
import hashlib, sys
import numpy as np
from repro.data.synthetic import make_letor_dataset

ds = make_letor_dataset("msn1", n_queries=40, seed=int(sys.argv[1]),
                        docs_scale=0.1)
h = hashlib.sha256()
for arr in (ds.X, ds.labels, ds.mask):
    h.update(np.ascontiguousarray(arr).tobytes())
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
print(h.hexdigest())
"""


def _digest_in_subprocess(seed: int) -> str:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(seed)],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "PYTHONHASHSEED": "random", "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr
    return proc.stdout.strip()


def _digest_in_process(seed: int) -> str:
    ds = make_letor_dataset("msn1", n_queries=40, seed=seed, docs_scale=0.1)
    h = hashlib.sha256()
    for arr in (ds.X, ds.labels, ds.mask):
        h.update(np.ascontiguousarray(arr).tobytes())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
    return h.hexdigest()


def test_same_seed_same_bytes_across_processes():
    """Fresh interpreters (randomized hash seed) reproduce this process's
    arrays byte for byte."""
    here = _digest_in_process(7)
    child_a = _digest_in_subprocess(7)
    child_b = _digest_in_subprocess(7)
    assert here == child_a == child_b


def test_different_seeds_differ():
    assert _digest_in_process(7) != _digest_in_process(8)


def test_splits_are_deterministic_partitions():
    """The 60/20/5/15 split is a pure function of the dataset: stable
    across calls, disjoint, and exhaustive."""
    ds = make_letor_dataset("msn1", n_queries=40, seed=3, docs_scale=0.1)
    a = ds.splits()
    b = ds.splits()
    total = 0
    for name in ("train", "classifier", "tune", "test"):
        np.testing.assert_array_equal(a[name].X, b[name].X)
        total += a[name].n_queries
    assert total == ds.n_queries
