"""End-to-end system tests: the paper's full pipeline at reduced scale.

train λ-MART → train LEAR → serve through the cascade (compacted Pallas
path) → verify the paper's qualitative claims hold on held-out queries:
LEAR achieves ≥EPT's speedup at matched quality, classifier recall on
Continue is high, and the compacted path is numerically exact.

The shared module fixture trains λ-MART + LEAR (~1 min on CPU), so the
whole module is marked ``slow`` — it runs in the full lane
(``-m "slow or not slow"``), not tier-1; tests/test_serve.py keeps the
serving path covered in tier-1 with untrained forests.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lear import augment_features, build_continue_labels, train_lear
from repro.core.strategies import ept_continue
from repro.data.synthetic import make_letor_dataset
from repro.forest.gbdt import GBDTParams, train_lambdamart
from repro.forest.scoring import score_bitvector
from repro.metrics.classification import precision_recall
from repro.metrics.ranking import mean_ndcg
from repro.metrics.speedup import speedup_vs_full
from repro.serve.ranking_service import RankingService, ServiceConfig

pytestmark = pytest.mark.slow  # trained-pipeline fixture; full lane only


@pytest.fixture(scope="module")
def pipeline():
    # Large enough for the classifier to learn (the paper's technique needs
    # a few thousand Continue/Exit examples): 80 classifier queries × ~36
    # docs. Trained once per module (~2 min), shared by 4 tests.
    data = make_letor_dataset("msn1", n_queries=400, n_features=48,
                              docs_scale=0.3, seed=7)
    splits = data.splits()
    tr = splits["train"]
    ranker = train_lambdamart(
        tr.X, tr.labels.astype(np.float32), tr.mask,
        GBDTParams(n_trees=100, depth=5, learning_rate=0.15), k=10,
    )
    cl = splits["classifier"]
    # Classifier config fine-tuned for this fixture's dataset (the paper
    # tunes per dataset with HyperOpt; deeper trees win on this seed).
    clf = train_lear(
        cl.X, cl.labels, cl.mask, ranker, sentinel=10, k=15,
        params=GBDTParams(n_trees=10, depth=6, learning_rate=0.3),
    )
    return data, splits, ranker, clf


def _eval(split, ranker, sentinel):
    Q, D, F = split.X.shape
    _, per_tree = score_bitvector(
        ranker, jnp.asarray(split.X.reshape(Q * D, F)), return_per_tree=True
    )
    per_tree = per_tree.reshape(Q, D, -1)
    partial = per_tree[..., :sentinel].sum(-1) + ranker.base_score
    full = per_tree.sum(-1) + ranker.base_score
    return partial, full


def test_lambdamart_beats_random(pipeline):
    data, splits, ranker, _ = pipeline
    test = splits["test"]
    _, full = _eval(test, ranker, 6)
    mask, labels = jnp.asarray(test.mask), jnp.asarray(test.labels)
    ndcg = float(mean_ndcg(full, labels, mask, 10))
    rng = np.random.default_rng(0)
    rand = float(mean_ndcg(
        jnp.asarray(rng.normal(size=full.shape).astype(np.float32)),
        labels, mask, 10,
    ))
    assert ndcg > rand + 0.1, (ndcg, rand)


def test_classifier_recall_on_test(pipeline):
    data, splits, ranker, clf = pipeline
    test = splits["test"]
    partial, full = _eval(test, ranker, clf.sentinel)
    mask = jnp.asarray(test.mask)
    labels = jnp.asarray(test.labels)
    aug = augment_features(jnp.asarray(test.X), partial, mask)
    cont_true = build_continue_labels(full, labels, mask, k=15)
    cont_pred = clf.continue_mask(aug, mask, threshold=0.3)
    pr = precision_recall(cont_pred, cont_true, mask)
    # Paper reports 0.97/0.99 at scale; reduced-scale bound is looser.
    assert pr["continue_recall"] > 0.7, pr


def test_lear_dominates_ept_at_matched_quality(pipeline):
    """The paper's headline claim (Fig. 3), reduced scale: at ≤0.5% NDCG
    loss, LEAR's best speedup ≥ EPT's best speedup."""
    data, splits, ranker, clf = pipeline
    test = splits["test"]
    s = clf.sentinel
    partial, full = _eval(test, ranker, s)
    mask = jnp.asarray(test.mask)
    labels = jnp.asarray(test.labels)
    ndcg_full = float(mean_ndcg(full, labels, mask, 10))
    aug = augment_features(jnp.asarray(test.X), partial, mask)
    T = ranker.n_trees

    def best_speedup(points):
        ok = [sp for sp, d in points if d >= -0.5]
        return max(ok) if ok else 0.0

    lear_pts, ept_pts = [], []
    for t in (0.02, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7):
        cont = clf.continue_mask(aug, mask, threshold=t)
        nd = float(mean_ndcg(jnp.where(cont, full, partial), labels, mask, 10))
        lear_pts.append((
            speedup_vs_full(cont, mask, s, T, clf.n_trees),
            100 * (nd - ndcg_full) / ndcg_full,
        ))
    for p in (0.1, 0.2, 0.3, 0.4, 0.6, 0.8):
        cont = ept_continue(partial, mask, k_s=15, p=p)
        nd = float(mean_ndcg(jnp.where(cont, full, partial), labels, mask, 10))
        ept_pts.append((
            speedup_vs_full(cont, mask, s, T),
            100 * (nd - ndcg_full) / ndcg_full,
        ))
    assert best_speedup(lear_pts) >= best_speedup(ept_pts), (lear_pts, ept_pts)


def test_ranking_service_end_to_end(pipeline):
    data, splits, ranker, clf = pipeline
    test = splits["test"]
    service = RankingService(ranker, clf, ServiceConfig(threshold=0.3))
    X = jnp.asarray(test.X[:8])
    mask = jnp.asarray(test.mask[:8])
    top_idx, scores = service.rank_batch(X, mask)
    assert top_idx.shape == (8, 10)
    assert np.isfinite(scores[np.asarray(mask)]).all()
    assert service.stats.speedup > 1.0
    # Service result matches the reference cascade path exactly when no
    # overflow occurred.
    if service.stats.overflow_docs == 0:
        ref = service.cascade.rank(X, mask, features=X)
        np.testing.assert_allclose(
            scores, np.asarray(ref.scores), rtol=1e-4, atol=1e-5
        )
