#!/usr/bin/env python
"""CLI entry for the tracer-safety analyzer (CI `invariants` job).

Equivalent to ``python -m repro.analysis`` but runnable from the repo
root without PYTHONPATH plumbing — it inserts ``src/`` itself.  Exits
nonzero on any unsuppressed finding.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"),
)

from repro.analysis import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
