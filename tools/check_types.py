#!/usr/bin/env python
"""Type-check lane for the core packages (CI `invariants` job).

Two tiers, so the lane is enforceable everywhere:

1. **Annotation completeness (always runs, stdlib-only).**  Every
   module- and class-level function in the target set must annotate all
   parameters and its return type.  Nested functions are exempt — they
   are traced closures whose operands are deliberately untyped tracers
   (and the tracer-safety taint analysis RELIES on that: unannotated
   params are treated as traced).  Waive a def line with
   ``# repro: noqa(TYP)``.
2. **mypy (runs when installed — the CI job installs it).**  Strictness
   is scoped in ``mypy.ini``: the target packages disallow untyped
   defs; jax/numpy internals are skipped (their stubs are not pinned in
   this environment, and the kernel surface is what we own).

Exit 0 only when every tier that ran is clean.
"""

from __future__ import annotations

import argparse
import ast
import importlib.util
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TARGETS = [
    "src/repro/kernels",
    "src/repro/core",
    "src/repro/serve",
    "src/repro/metrics",
    "src/repro/analysis",
    "src/repro/typecheck.py",
    "benchmarks/check_bench.py",
    "benchmarks/bench_serve.py",
]

NOQA_TYP_RE = re.compile(r"#\s*repro:\s*noqa\(\s*TYP\s*\)")


def _target_files() -> list[str]:
    files: list[str] = []
    for target in TARGETS:
        path = os.path.join(REPO, target)
        if os.path.isdir(path):
            for root, _dirs, names in os.walk(path):
                files.extend(
                    os.path.join(root, n) for n in names if n.endswith(".py")
                )
        else:
            files.append(path)
    return sorted(files)


def check_annotations(files: list[str]) -> list[str]:
    problems: list[str] = []
    for path in files:
        with open(path) as handle:
            source = handle.read()
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as exc:
            problems.append(f"{path}:1: TYP000 unparseable: {exc}")
            continue
        lines = source.splitlines()
        rel = os.path.relpath(path, REPO)
        for func in _top_level_functions(tree):
            line = lines[func.lineno - 1] if func.lineno <= len(lines) else ""
            if NOQA_TYP_RE.search(line):
                continue
            args = [
                *func.args.posonlyargs, *func.args.args,
                *func.args.kwonlyargs,
                *([func.args.vararg] if func.args.vararg else []),
                *([func.args.kwarg] if func.args.kwarg else []),
            ]
            for arg in args:
                if arg.arg in ("self", "cls"):
                    continue
                if arg.annotation is None:
                    problems.append(
                        f"{rel}:{func.lineno}: TYP001 `{func.name}` "
                        f"parameter `{arg.arg}` is unannotated"
                    )
            if func.returns is None:
                problems.append(
                    f"{rel}:{func.lineno}: TYP002 `{func.name}` has no "
                    "return annotation"
                )
    marker = os.path.join(REPO, "src", "repro", "py.typed")
    if not os.path.exists(marker):
        problems.append("src/repro/py.typed: TYP003 marker file missing")
    return problems


def _top_level_functions(tree: ast.Module):
    """Module-level functions and class methods; nested defs excluded."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield item


def run_mypy(files: list[str]) -> int:
    if importlib.util.find_spec("mypy") is None:
        print("check_types: mypy not installed — annotation tier only")
        return 0
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [
            sys.executable, "-m", "mypy",
            "--config-file", os.path.join(REPO, "mypy.ini"),
            *files,
        ],
        cwd=REPO, env=env,
    )
    return proc.returncode


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    parser.add_argument(
        "--no-mypy", action="store_true",
        help="run only the stdlib annotation-completeness tier",
    )
    args = parser.parse_args(argv)

    files = _target_files()
    problems = check_annotations(files)
    for problem in problems:
        print(problem)
    status = 1 if problems else 0
    if not args.no_mypy:
        status = max(status, run_mypy(files))
    if status == 0:
        print(f"check_types: OK ({len(files)} files)")
    return status


if __name__ == "__main__":
    sys.exit(main())
